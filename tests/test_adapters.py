import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adapters as ad
from repro.core import peft
from repro.core.orthogonal import orthogonality_error


KEY = jax.random.PRNGKey(0)
METHODS = ["gsoft", "double_gsoft", "oft", "boft", "lora"]


def _spec(method, d_in=32, d_out=24, **kw):
    kw.setdefault("block_size", 8)
    return ad.AdapterSpec(method=method, d_in=d_in, d_out=d_out, **kw)


@pytest.mark.parametrize("method", METHODS)
def test_identity_init(method):
    """At init, W_eff must equal W exactly (paper: Q = I via K = 0)."""
    spec = _spec(method)
    params = ad.init_adapter(spec, KEY)
    W = jax.random.normal(jax.random.PRNGKey(1), (32, 24))
    W_eff = ad.materialize(spec, params, W)
    assert np.allclose(np.asarray(W_eff), np.asarray(W), atol=1e-6)


@pytest.mark.parametrize("method", ["gsoft", "double_gsoft", "oft", "boft"])
def test_orthogonal_methods_preserve_geometry(method):
    """Orthogonal W' = Q W preserves singular values & pairwise neuron angles."""
    spec = _spec(method, d_in=32, d_out=16)
    params = ad.init_adapter(spec, KEY)
    params = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(2), p.shape) * 0.3, params)
    W = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    W_eff = ad.materialize(spec, params, W)
    s0 = np.linalg.svd(np.asarray(W), compute_uv=False)
    s1 = np.linalg.svd(np.asarray(W_eff), compute_uv=False)
    assert np.allclose(s0, s1, atol=1e-4)
    # gram of columns (pairwise angles of neurons) is preserved for
    # input-side rotations
    if method != "double_gsoft":
        g0 = np.asarray(W).T @ np.asarray(W)
        g1 = np.asarray(W_eff).T @ np.asarray(W_eff)
        assert np.allclose(g0, g1, atol=1e-4)


def test_double_gsoft_changes_both_sides():
    spec = _spec("double_gsoft", d_in=32, d_out=16, block_size=4)
    params = ad.init_adapter(spec, KEY)
    params = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(5), p.shape) * 0.3, params)
    W = jax.random.normal(jax.random.PRNGKey(4), (32, 16))
    W_eff = np.asarray(ad.materialize(spec, params, W))
    # U and V spaces both rotated: neither W'W^T ~ WW^T nor W'^T W ~ W^T W
    assert not np.allclose(W_eff @ W_eff.T, np.asarray(W) @ np.asarray(W).T, atol=1e-3)
    # but singular values still preserved
    s0 = np.linalg.svd(np.asarray(W), compute_uv=False)
    s1 = np.linalg.svd(W_eff, compute_uv=False)
    assert np.allclose(s0, s1, atol=1e-4)


def test_lora_matches_reference():
    spec = _spec("lora", rank=4, alpha=8.0)
    params = ad.init_adapter(spec, KEY)
    params["B"] = jax.random.normal(jax.random.PRNGKey(6), params["B"].shape)
    W = jnp.zeros((32, 24))
    W_eff = ad.materialize(spec, params, W)
    ref = (8.0 / 4.0) * np.asarray(params["A"]) @ np.asarray(params["B"])
    assert np.allclose(np.asarray(W_eff), ref, atol=1e-5)


def test_batched_adapters_vmap():
    """Scan-stacked layers (L, d, n) and MoE (L, E, d, n) weights."""
    for batch in [(3,), (2, 4)]:
        spec = _spec("gsoft", batch=batch)
        params = ad.init_adapter(spec, KEY)
        assert params["L"].shape[:len(batch)] == batch
        W = jax.random.normal(jax.random.PRNGKey(7), batch + (32, 24))
        W_eff = ad.materialize(spec, params, W)
        assert W_eff.shape == W.shape
        assert np.allclose(np.asarray(W_eff), np.asarray(W), atol=1e-6)


def test_activation_side_equivalence():
    """x @ (Q W) == (x Q) @ W — the two application modes agree."""
    spec = _spec("gsoft", d_in=32, d_out=24)
    params = ad.init_adapter(spec, KEY)
    params = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(8), p.shape) * 0.2, params)
    W = jax.random.normal(jax.random.PRNGKey(9), (32, 24))
    x = jax.random.normal(jax.random.PRNGKey(10), (5, 32))
    y_weight = x @ ad.materialize(spec, params, W)
    y_act = ad.apply_activation_side(spec, params, x) @ W
    assert np.allclose(np.asarray(y_weight), np.asarray(y_act), atol=1e-4)


def test_merge_equals_materialize():
    spec = _spec("gsoft")
    params = ad.init_adapter(spec, KEY)
    W = jax.random.normal(jax.random.PRNGKey(11), (32, 24))
    assert np.allclose(np.asarray(ad.merge(spec, params, W)),
                       np.asarray(ad.materialize(spec, params, W)))


def test_butterfly_sigma_valid():
    from repro.core.permutations import is_permutation
    for level in (1, 2, 3):
        sig = ad.butterfly_sigma(32, 8, level)
        assert is_permutation(sig)
    # level 1 is the identity grouping (contiguous blocks)
    assert np.all(ad.butterfly_sigma(32, 8, 1) == np.arange(32))


def test_boft_density_needs_log2_factors():
    """BOFT needs 1+log2(r) factors; GSOFT needs only 2 (paper §5.2)."""
    import math
    from repro.core import gs
    d, b = 64, 8  # r = 8
    # materialize BOFT support with random params and count zeros
    m_dense = 1 + int(math.log2(d // b))
    for m, expect_dense in [(m_dense, True), (2, False)]:
        spec = _spec("boft", d_in=d, d_out=d, block_size=b, boft_factors=m)
        params = ad.init_adapter(spec, KEY)
        params["K"] = jax.random.normal(jax.random.PRNGKey(12),
                                        params["K"].shape) * 0.3
        Q = np.asarray(ad.materialize(spec, params, jnp.eye(d)))
        assert (np.abs(Q) > 1e-9).all() == expect_dense
    # GSOFT m=2 is already dense for r <= b
    spec = _spec("gsoft", d_in=d, d_out=d, block_size=b)
    params = ad.init_adapter(spec, KEY)
    params = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(13), p.shape) * 0.3, params)
    Q = np.asarray(ad.materialize(spec, params, jnp.eye(d)))
    assert (np.abs(Q) > 1e-9).all()


def test_boft_orthogonality():
    spec = _spec("boft", d_in=32, d_out=32, block_size=8, boft_factors=3)
    params = ad.init_adapter(spec, KEY)
    params["K"] = jax.random.normal(jax.random.PRNGKey(14), params["K"].shape) * 0.3
    Q = ad.materialize(spec, params, jnp.eye(32))
    assert float(orthogonality_error(Q[None])) < 1e-4


def test_neumann_order_close_to_exact():
    spec = _spec("gsoft")
    spec_n = dataclasses.replace(spec, neumann_order=8)
    params = ad.init_adapter(spec, KEY)
    params = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(15), p.shape) * 0.02, params)
    W = jax.random.normal(jax.random.PRNGKey(16), (32, 24))
    exact = np.asarray(ad.materialize(spec, params, W))
    approx = np.asarray(ad.materialize(spec_n, params, W))
    assert np.abs(exact - approx).max() < 1e-4


# ---------------------------------------------------------------------------
# PEFT engine over trees
# ---------------------------------------------------------------------------

def _toy_params():
    k = jax.random.PRNGKey(17)
    return {
        "embed": {"table": jax.random.normal(k, (50, 16))},
        "layers": {
            "attn": {"wq": jax.random.normal(k, (2, 16, 16)),
                     "wo": jax.random.normal(k, (2, 16, 16))},
            "mlp": {"wi": jax.random.normal(k, (2, 16, 32)),
                    "wo": jax.random.normal(k, (2, 32, 16)),
                    "norm": jnp.ones((2, 16))},
        },
    }


def test_peft_target_selection():
    cfg = peft.PEFTConfig(method="gsoft", block_size=4)
    params = _toy_params()
    specs = peft.adapted_paths(cfg, params)
    assert set(specs) == {"layers/attn/wq", "layers/attn/wo",
                          "layers/mlp/wi", "layers/mlp/wo"}
    assert specs["layers/mlp/wi"].batch == (2,)
    assert specs["layers/mlp/wi"].d_in == 16 and specs["layers/mlp/wi"].d_out == 32


def test_peft_materialize_identity_and_grads():
    cfg = peft.PEFTConfig(method="gsoft", block_size=4)
    params = _toy_params()
    adapters = peft.init_peft(cfg, params, KEY)
    eff = peft.materialize_tree(cfg, params, adapters)
    for p, v in peft.flatten_paths(eff).items():
        assert np.allclose(np.asarray(v),
                           np.asarray(peft.flatten_paths(params)[p]), atol=1e-6)

    # gradient flows to adapters through materialize. NB: a sum-of-squares
    # loss is *invariant* under orthogonal Q (that's the point of the method)
    # so probe with a linear functional instead.
    probe = jax.random.normal(jax.random.PRNGKey(99), (2, 16, 16))

    def loss(adp):
        e = peft.materialize_tree(cfg, params, adp)
        return jnp.sum(e["layers"]["attn"]["wq"] * probe)

    g = jax.grad(loss)(adapters)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert gnorm > 0


def test_peft_param_budget_ratio():
    """Adapters must be a tiny fraction of the base model."""
    cfg = peft.PEFTConfig(method="gsoft", block_size=4)
    params = _toy_params()
    adapters = peft.init_peft(cfg, params, KEY)
    assert peft.count_params(adapters) < 0.6 * peft.count_params(params)


def test_paper_table1_param_counts():
    """RoBERTa-base GLUE adapter budgets (paper Table 1): GSOFT_b=8 and
    BOFT_m=2,b=8 both cost 2*d*b per adapted weight -> identical budgets;
    LoRA_r=8 costs r*(d_in+d_out)."""
    d, dff, L = 768, 3072, 12
    per_layer_gsoft = 4 * (2 * d * 8) + (2 * d * 8) + (2 * dff * 8)
    total_gsoft = L * per_layer_gsoft
    per_layer_lora = 4 * 8 * (d + d) + 8 * (d + dff) + 8 * (dff + d)
    total_lora = L * per_layer_lora
    assert total_gsoft == total_lora == 1327104  # ~1.33M, paper reports 1.42M
    # (paper counts include classifier-head adapters; ratio GSOFT == BOFT m=2
    # == LoRA r=8 is the claim being validated)

    cfg = peft.PEFTConfig(method="gsoft", block_size=8)
    W = {"attn": {"wq": jnp.zeros((d, d))}}
    adapters = peft.init_peft(cfg, W, KEY)
    assert peft.count_params(adapters) == 2 * d * 8


def test_target_pattern_fullmatch_rejects_decoy_weights():
    """Regression: the old ``re.search`` fallback in ``_matches`` ignored
    the end anchor, so an unanchored target like ``.*/wq`` also adapted a
    decoy weight named ``.../wq_extra``. Matching is fullmatch-only now."""
    cfg = peft.PEFTConfig(method="gsoft", block_size=4,
                          target_patterns=(r".*/wq",))
    params = {"layers": {"attn": {
        "wq": jnp.zeros((8, 8)),
        "wq_extra": jnp.zeros((8, 8)),     # decoy: must NOT be adapted
        "pre_wq": jnp.zeros((8, 8)),       # suffix decoy: also excluded
    }}}
    assert set(peft.adapted_paths(cfg, params)) == {"layers/attn/wq"}
    # the shipped DEFAULT_TARGETS keep matching the real projections
    dcfg = peft.PEFTConfig(method="gsoft", block_size=4)
    tree = {"layers": {"mamba": {"in_proj": jnp.zeros((8, 8)),
                                 "in_projector": jnp.zeros((8, 8))}}}
    assert set(peft.adapted_paths(dcfg, tree)) == {"layers/mamba/in_proj"}
