"""End-to-end train loop: learning, checkpoint/resume exactness, heartbeat."""
import os

import jax
import numpy as np
import pytest

from repro import optim
from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.data import DataConfig
from repro.train.loop import LoopConfig, train
from repro.train.steps import TrainStepConfig

CFG = ModelConfig(
    name="tiny-lm", family="decoder", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    mlp_type="swiglu", dtype="f32", param_dtype="f32", remat="none",
    attn_chunk=32)


def _tcfg():
    return TrainStepConfig(
        peft=peft_lib.PEFTConfig(method="gsoft", block_size=8),
        opt=optim.OptimizerConfig(learning_rate=3e-3),
        num_microbatches=2)


def _dcfg():
    return DataConfig(seq_len=32, global_batch=8, vocab_size=128)


def test_training_reduces_loss(tmp_path):
    loop = LoopConfig(steps=30, log_every=5, ckpt_every=100,
                      ckpt_dir=str(tmp_path),
                      heartbeat_path=str(tmp_path / "hb"))
    out = train(CFG, _tcfg(), _dcfg(), loop, log_fn=lambda s: None)
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"] * 0.9
    assert os.path.exists(tmp_path / "hb")


def test_resume_is_exact(tmp_path):
    """3+3 steps with restart == 6 straight steps (deterministic data +
    checkpointed optimizer/adapters)."""
    loop_a = LoopConfig(steps=6, log_every=1, ckpt_every=100,
                        ckpt_dir=str(tmp_path / "a"))
    straight = train(CFG, _tcfg(), _dcfg(), loop_a, log_fn=lambda s: None)

    loop_b1 = LoopConfig(steps=3, log_every=1, ckpt_every=3,
                         ckpt_dir=str(tmp_path / "b"))
    train(CFG, _tcfg(), _dcfg(), loop_b1, log_fn=lambda s: None)
    loop_b2 = LoopConfig(steps=6, log_every=1, ckpt_every=3,
                         ckpt_dir=str(tmp_path / "b"))
    resumed = train(CFG, _tcfg(), _dcfg(), loop_b2, resume=True,
                    log_fn=lambda s: None)

    a = jax.tree.leaves(straight["trainable"])
    b = jax.tree.leaves(resumed["trainable"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-6, rtol=1e-6)


def test_full_finetune_mode(tmp_path):
    tcfg = TrainStepConfig(peft=peft_lib.PEFTConfig(method="full"),
                           opt=optim.OptimizerConfig(learning_rate=1e-3),
                           num_microbatches=1)
    loop = LoopConfig(steps=8, log_every=2, ckpt_every=100)
    out = train(CFG, tcfg, _dcfg(), loop, log_fn=lambda s: None)
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]
