"""Paged KV cache (ISSUE 7): page pool, paged engine vs contiguous,
chunked prefill, shared-prefix reuse, paged flash-decode kernel, and the
autotuner persistence round trip."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.kernels import dispatch
from repro.kernels.flash_attention import paged_flash_decode
from repro.kernels.ref import paged_attn_ref
from repro.serve.engine import PagedServeEngine, ServeEngine, \
    StaticServeEngine
from repro.serve.kv import GARBAGE_PAGE, KVPagePool
from repro.store import AdapterStore

CFG = get_smoke_config("qwen2-72b")
RT = ModelRuntime(CFG, key=jax.random.PRNGKey(0))


def _solo(prompt, max_new, eos_id=-1):
    eng = StaticServeEngine(RT, max_batch=1, max_len=64, eos_id=eos_id)
    rid = eng.add_request(list(prompt), max_new_tokens=max_new)
    return eng.run()[rid]


def _paged(rt=RT, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 48)
    kw.setdefault("eos_id", -1)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return PagedServeEngine(rt, **kw)


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------

def test_pool_alloc_free_roundtrip():
    pool = KVPagePool(num_pages=9, page_size=8)
    assert pool.available == 8                      # page 0 is garbage
    sp = pool.admit(None, list(range(10)), max_new=6)   # 16 tok -> 2 pages
    assert sp is not None and len(sp.pages) == 2
    assert GARBAGE_PAGE not in sp.pages
    assert pool.available == 6
    pool.finish(sp)
    assert pool.available == 8                      # private pages -> free


def test_pool_stall_when_exhausted():
    pool = KVPagePool(num_pages=5, page_size=8)     # 4 usable pages
    sp = pool.admit(None, list(range(20)), max_new=8)   # 28 tok -> 4 pages
    assert sp is not None
    assert pool.admit(None, [1, 2, 3], max_new=8) is None
    assert pool.stats()["kv_stalls"] == 1
    pool.finish(sp)
    assert pool.admit(None, [1, 2, 3], max_new=8) is not None


def test_pool_table_row_pads_with_garbage():
    pool = KVPagePool(num_pages=9, page_size=8)
    sp = pool.admit(None, list(range(9)), max_new=2)    # 11 tok -> 2 pages
    row = pool.table_row(sp, width=5)
    assert row.dtype == np.int32 and row.shape == (5,)
    assert list(row[:2]) == sp.pages
    assert all(p == GARBAGE_PAGE for p in row[2:])


def test_pool_shared_prefix_refcount_two_tenants():
    """Two tenants, identical 16-token prefix, divergent suffixes: full
    prefix pages are shared (refcount 2) while the divergent tail stays
    private, so decode writes never alias across tenants."""
    pool = KVPagePool(num_pages=17, page_size=8)
    prefix = list(range(100, 116))                  # 2 full pages
    a = pool.admit("t", prefix + [1, 2, 3], max_new=4)
    pool.register(a)
    b = pool.admit("t", prefix + [7, 8, 9], max_new=4)
    assert b.n_cached == 16                         # both full pages claimed
    assert b.pages[:2] == a.pages[:2]               # shared
    assert b.pages[2:] != a.pages[2:]               # divergent tail private
    for pid in a.pages[:2]:
        assert pool._refs[pid] == 2
    avail = pool.available
    pool.finish(a)
    pool.finish(b)
    assert pool.available > avail                   # everything reclaimable


def test_pool_partial_page_never_shared():
    """A prefix hit never extends into a partially-filled page: tenant B
    with a 12-token common prefix (page 1 only half full) claims just the
    first full page."""
    pool = KVPagePool(num_pages=17, page_size=8)
    a = pool.admit("t", list(range(12)), max_new=4)
    pool.register(a)
    b = pool.admit("t", list(range(12)) + [99], max_new=4)
    assert b.n_cached == 8                          # only page 0 shared
    assert b.pages[0] == a.pages[0]
    assert b.pages[1] != a.pages[1]


def test_pool_cache_eviction_retires_hash():
    pool = KVPagePool(num_pages=5, page_size=8)     # 4 usable pages
    a = pool.admit(None, list(range(8)), max_new=8)     # 2 pages, published
    pool.register(a)
    pool.finish(a)                                  # -> reusable, cached
    b = pool.admit(None, list(range(200, 232)), max_new=0)  # needs all 4
    assert b is not None
    assert pool.stats()["cache_evictions"] > 0


# ---------------------------------------------------------------------------
# paged engine == contiguous engine
# ---------------------------------------------------------------------------

def test_paged_matches_continuous_mixed_lengths():
    """Greedy tokens identical to the contiguous engine on ragged traffic,
    including prompts long enough to need several prefill chunks."""
    rng = np.random.default_rng(3)
    wl = [(rng.integers(1, 200, size=n).tolist(), m)
          for n, m in ((5, 4), (19, 6), (3, 8), (26, 3), (11, 5), (7, 7))]

    def serve(eng):
        rids = [eng.add_request(p, max_new_tokens=m) for p, m in wl]
        res = eng.run()
        return [res[r] for r in rids]

    ref = serve(ServeEngine(RT, max_batch=3, max_len=48, eos_id=-1))
    got = serve(_paged())
    assert got == ref


def test_multi_chunk_prompt_matches_solo():
    prompt = list(range(1, 20))                     # 19 tok, chunk 8 -> 3
    eng = _paged(max_batch=1)
    rid = eng.add_request(prompt, max_new_tokens=6)
    assert eng.run()[rid] == _solo(prompt, 6)


def test_eos_refill_reuses_freed_pages():
    """EOS terminates early, freed pages are recycled for queued requests,
    outputs still match solo references, and the pool drains clean."""
    probe = _solo([5, 6, 7], 8)
    eos = next(t for t in probe if t != probe[0])
    prompts = [[5, 6, 7], [9, 10, 11, 12], [3, 4], [8, 2, 6, 1], [13, 14]]
    solo = [_solo(p, 8, eos_id=eos) for p in prompts]
    # tight pool: ~2 concurrent requests' worth, so serving 5 forces reuse
    eng = _paged(max_batch=2, num_pages=7, eos_id=eos)
    rids = [eng.add_request(p, max_new_tokens=8) for p in prompts]
    res = eng.run()
    assert [res[r] for r in rids] == solo
    assert any(len(out) < 8 for out in solo)        # EOS actually fired
    st = eng.kv_stats()
    assert st["alloc"] > 6                          # more allocs than pages
    assert eng.pool.available == 6                  # fully reclaimed


def test_shared_prefix_engine_hits_and_matches_solo():
    sys_prompt = list(range(40, 56))                # 2 full pages at ps=8
    p1, p2 = sys_prompt + [1, 2, 3], sys_prompt + [7, 8]
    eng = _paged(max_batch=1)
    r1 = eng.add_request(p1, max_new_tokens=5)
    out1 = eng.run()[r1]
    r2 = eng.add_request(p2, max_new_tokens=5)
    out2 = eng.run()[r2]
    assert eng.kv_stats()["prefix_hits"] >= 2
    assert out1 == _solo(p1, 5)
    assert out2 == _solo(p2, 5)


# ---------------------------------------------------------------------------
# paged flash-decode kernel
# ---------------------------------------------------------------------------

def test_paged_flash_decode_matches_ref():
    b, h, kh, d, ps, npages, w = 3, 4, 2, 16, 8, 11, 5
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (npages, ps, kh, d), jnp.float32)
    vp = jax.random.normal(ks[2], (npages, ps, kh, d), jnp.float32)
    table = jnp.asarray(
        np.random.default_rng(0).integers(0, npages, size=(b, w)), jnp.int32)
    kv_len = jnp.asarray([1, 17, 40], jnp.int32)
    ref = paged_attn_ref(q, kp, vp, table, kv_len)
    got = paged_flash_decode(q, kp, vp, table, kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# engine hot loop: adapter context cached across decode steps
# ---------------------------------------------------------------------------

def test_adapter_context_cached_until_bank_version_bumps():
    pcfg = {"a0": peft_lib.PEFTConfig(method="gsoft", block_size=8),
            "a1": peft_lib.PEFTConfig(method="boft", block_size=8)}
    ads = {n: peft_lib.init_peft(c, RT.params, jax.random.PRNGKey(i))
           for i, (n, c) in enumerate(pcfg.items())}
    store = AdapterStore.from_adapters(ads, pcfg)
    rt = RT.attach(store, hbm_budget=2)
    eng = ServeEngine(rt, max_batch=2, max_len=32, eos_id=-1)
    c1 = eng._context()
    assert eng._context() is c1                     # cache hit, no host work
    rt.bank.version += 1                            # page-in/evict happened
    c2 = eng._context()
    assert c2 is not c1
    assert eng._context() is c2


# ---------------------------------------------------------------------------
# autotuner persistence
# ---------------------------------------------------------------------------

def test_tuning_cache_roundtrip(tmp_path, monkeypatch):
    path = str(tmp_path / "tunings.json")
    key = dispatch.paged_attn_key(4, 2, 16, 8, jnp.float32, backend="cpu")
    saved_tuned = dict(dispatch._TUNED)
    try:
        dispatch._TUNED.clear()
        dispatch._TUNED[key] = dispatch.Tuning(token_tile=64, group_tile=2)
        assert dispatch.save_tuning_cache(path) == path

        dispatch._TUNED.clear()
        assert dispatch.load_tuning_cache(path) == 1
        assert dispatch._TUNED[key] == dispatch.Tuning(64, 2)

        # results timed in-process win over the cache on reload
        dispatch._TUNED[key] = dispatch.Tuning(token_tile=256)
        assert dispatch.load_tuning_cache(path) == 0
        assert dispatch._TUNED[key].token_tile == 256

        # env-driven lazy load on first resolution
        dispatch._TUNED.clear()
        monkeypatch.setenv(dispatch.TUNING_CACHE_ENV, path)
        monkeypatch.setattr(dispatch, "_cache_loaded", False)
        assert dispatch.get_tuning(key) == dispatch.Tuning(64, 2)
    finally:
        dispatch._TUNED.clear()
        dispatch._TUNED.update(saved_tuned)


def test_tuning_cache_missing_file_is_noop(tmp_path):
    assert dispatch.load_tuning_cache(str(tmp_path / "absent.json")) == 0
    assert dispatch.save_tuning_cache(None) is None


# ---------------------------------------------------------------------------
# guard mirror: contiguous max_len allocation stays behind the runtime facade
# ---------------------------------------------------------------------------

def test_no_contiguous_kv_alloc_outside_runtime():
    """``init_decode_state(`` is the contiguous max_len allocator; serving,
    launch, bench, and example code must go through ``rt.decode_state`` /
    ``rt.paged_state`` so the KV residency policy lives in one place
    (mirrors the CI grep guard)."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    pat = re.compile(r"init_decode_state\s*\(")
    offenders, scanned = [], 0
    for sub in ("src/repro/serve", "src/repro/launch", "benchmarks",
                "examples"):
        for f in (root / sub).rglob("*.py"):
            scanned += 1
            for i, line in enumerate(f.read_text().splitlines(), 1):
                if pat.search(line):
                    offenders.append(f"{f}:{i}")
    assert scanned > 8
    assert not offenders, offenders
