import math

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core import gs
from repro.core.permutations import PermSpec


def _random_layout(rng):
    """Random small GS layout with compatible chained dims."""
    kL = int(rng.integers(1, 5))
    kR = int(rng.integers(1, 5))
    # inner dim s must satisfy kL * bL2 == kR * bR1 == s
    s = int(np.lcm(kL, kR)) * int(rng.integers(1, 4))
    bL2 = s // kL
    bR1 = s // kR
    bL1 = int(rng.integers(1, 5))
    bR2 = int(rng.integers(1, 5))
    lspec = gs.BlockDiagSpec(kL, bL1, bL2)
    rspec = gs.BlockDiagSpec(kR, bR1, bR2)
    sigma = rng.permutation(s)
    return gs.GSLayout(
        lspec=lspec, rspec=rspec,
        perm_left=PermSpec.from_sigma(rng.permutation(lspec.out_dim)),
        perm_mid=PermSpec.from_sigma(sigma),
        perm_right=PermSpec.from_sigma(rng.permutation(rspec.in_dim)),
    )


@pytest.mark.parametrize("seed", range(6))
def test_apply_matches_materialize(seed):
    rng = np.random.default_rng(seed)
    layout = _random_layout(rng)
    L = jnp.asarray(rng.normal(size=layout.lspec.param_shape), jnp.float32)
    R = jnp.asarray(rng.normal(size=layout.rspec.param_shape), jnp.float32)
    x = rng.normal(size=(3, layout.in_dim)).astype(np.float32)
    y = np.asarray(gs.gs_apply(layout, L, R, jnp.asarray(x)))
    A = gs.gs_materialize(layout, L, R)
    assert np.allclose(y, x @ A.T, atol=1e-4)


@pytest.mark.parametrize("seed", range(4))
def test_apply_T_matches_materialize(seed):
    rng = np.random.default_rng(seed + 10)
    layout = _random_layout(rng)
    L = jnp.asarray(rng.normal(size=layout.lspec.param_shape), jnp.float32)
    R = jnp.asarray(rng.normal(size=layout.rspec.param_shape), jnp.float32)
    x = rng.normal(size=(2, layout.out_dim)).astype(np.float32)
    y = np.asarray(gs.gs_apply_T(layout, L, R, jnp.asarray(x)))
    A = gs.gs_materialize(layout, L, R)
    assert np.allclose(y, x @ A, atol=1e-4)


def test_gs_matmul_weight_side():
    rng = np.random.default_rng(3)
    layout = gs.gsoft_layout(12, 4)
    L = jnp.asarray(rng.normal(size=layout.lspec.param_shape), jnp.float32)
    R = jnp.asarray(rng.normal(size=layout.rspec.param_shape), jnp.float32)
    W = rng.normal(size=(12, 7)).astype(np.float32)
    got = np.asarray(gs.gs_matmul(layout, L, R, jnp.asarray(W)))
    A = gs.gs_materialize(layout, L, R)
    assert np.allclose(got, A @ W, atol=1e-4)


@pytest.mark.parametrize("seed", range(5))
def test_proposition1_block_lowrank(seed):
    """Prop. 1: L P R written as block matrix of sums of outer products."""
    rng = np.random.default_rng(seed + 20)
    layout = _random_layout(rng)
    # restrict to GS(I, P, I) as in the proposition
    layout = gs.GSLayout(layout.lspec, layout.rspec, PermSpec.identity(),
                         layout.perm_mid, PermSpec.identity())
    L = rng.normal(size=layout.lspec.param_shape)
    R = rng.normal(size=layout.rspec.param_shape)
    direct = gs.gs_materialize(layout, L, R)
    via_prop = gs.lowrank_blocks(layout, L, R)
    assert np.allclose(direct, via_prop, atol=1e-10)


def test_block_ranks_figure2_example():
    """Paper Fig. 2: kL=4 (3x3), kR=2 (6x6), P = P_(4,12)."""
    layout = gs.GSLayout(
        lspec=gs.BlockDiagSpec(4, 3, 3),
        rspec=gs.BlockDiagSpec(2, 6, 6),
        perm_left=PermSpec.identity(),
        perm_mid=PermSpec.gs(4),
        perm_right=PermSpec.identity(),
    )
    ranks = gs.block_ranks(layout)
    # each of the 4x2 blocks receives 12/8 -> either 1 or 2 rank-1 terms,
    # totals must sum to the inner dim
    assert ranks.sum() == 12
    assert ranks.shape == (4, 2)


def test_monarch_constraint_not_required():
    """App. C: GS supports equal square blocks in L and R (Monarch cannot
    unless kL*kR = n). Example: n=16, kL=kR=4, b=4 -> Monarch would need
    b_R = k_L = 4 AND k_R * b_R2 = n with b_L = k_R... satisfied only when
    kL*kR=n; here kL*kR=16=n is fine, so pick kL=kR=2, b=8: kL*kR=4 != 16."""
    layout = gs.gsoft_layout(16, 8)  # r=2 blocks of 8: Monarch would need b=k
    assert layout.lspec.num_blocks == 2 and layout.lspec.rows == 8
    # structurally valid and applies fine
    rng = np.random.default_rng(0)
    L = jnp.asarray(rng.normal(size=layout.lspec.param_shape), jnp.float32)
    R = jnp.asarray(rng.normal(size=layout.rspec.param_shape), jnp.float32)
    x = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    assert gs.gs_apply(layout, L, R, x).shape == (16,)


# ---------------------------------------------------------------------------
# Theorem 2 — density
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,r", [(2, 4), (4, 4), (2, 8), (4, 16), (3, 9)])
def test_theorem2_density(b, r):
    d = b * r
    m = gs.min_factors_dense(b, r)
    assert m == 1 + math.ceil(math.log(r, b) - 1e-12)
    dense = gs.gs_order_layout(d, b, m)
    assert gs.is_dense_class(dense)
    if m > 1:
        thin = gs.gs_order_layout(d, b, m - 1)
        assert not gs.is_dense_class(thin)


def test_theorem2_beats_butterfly_count():
    # paper's 1024/b=32 example: butterfly needs 6 factors, GS needs 2
    b, r = 32, 32
    assert gs.min_factors_dense(b, r) == 2
    butterfly = 1 + math.ceil(math.log2(r))
    assert butterfly == 6


def test_gsoft_layout_dense_when_r_le_b():
    layout = gs.gsoft_layout(64, 8)  # r = 8 = b -> dense with m=2
    factors = gs.GSFactors(
        specs=(layout.rspec, layout.lspec),
        perms=(layout.perm_right, layout.perm_mid, layout.perm_left))
    assert gs.is_dense_class(factors)


def test_pick_block_size():
    assert gs.pick_block_size(1024, 32) == 32
    b = gs.pick_block_size(12288, 64)
    assert 12288 % b == 0 and 12288 // b <= b <= 64 or b <= 64
    # density condition honored when possible
    assert 12288 // b <= b or all(
        not (x <= 64 and 12288 // x <= x) for x in range(1, 12289) if 12288 % x == 0)


def test_higher_order_apply_matches_materialize():
    rng = np.random.default_rng(7)
    d, b, m = 27, 3, 3
    factors = gs.gs_order_layout(d, b, m)
    blocks = [jnp.asarray(rng.normal(size=s.param_shape), jnp.float32)
              for s in factors.specs]
    x = rng.normal(size=(2, d)).astype(np.float32)
    y = np.asarray(gs.gs_factors_apply(factors, blocks, jnp.asarray(x)))
    A = gs.gs_factors_materialize(factors, blocks)
    assert np.allclose(y, x @ A.T, atol=1e-4)


def test_block_diag_matmul_param_count():
    # paper §5.2: GS uses 2*b^3*r params vs butterfly 6*b^3*r at d=1024,b=32
    layout = gs.gsoft_layout(1024, 32)
    assert layout.num_params == 2 * 32 ** 3 * (1024 // 32) // 32
    assert layout.num_params == 2 * 1024 * 32
