"""Serve-time tensor-parallel checks (ISSUE 8). Runs under
XLA_FLAGS=--xla_force_host_platform_device_count=8 in a subprocess
(tests/test_distributed.py drives it): a ``ModelRuntime`` built on a tp
mesh must serve TOKEN-IDENTICAL to the single-device runtime through the
real engines — contiguous ServeEngine with a mixed-method eager bank
(tp=2 divides the smoke model's heads, tp=4 exceeds its kv heads so the
KV spec falls back to replicated), the int8-quantized runtime (QuantTensor
trees placed leaf-wise), and the paged engine (KV pages head-sharded,
page table replicated). Prints one JSON line per check."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.distrib import serve_mesh
from repro.launch.serve import make_demo_adapters
from repro.serve.engine import PagedServeEngine, ServeEngine

OUT = []


def check(name, ok, **kw):
    OUT.append({"name": name, "ok": bool(ok), **kw})


def workload(n_req, seed=0, adapters=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_req):
        req = {"prompt": rng.integers(1, 200, size=int(
                   rng.integers(4, 13))).tolist(),
               "max_new_tokens": int(rng.integers(2, 11))}
        if adapters:
            req["adapter"] = adapters[i % len(adapters)]
        reqs.append(req)
    return reqs


def run_engine(rt, wl, paged=False):
    if paged:
        eng = PagedServeEngine(rt, max_batch=4, max_len=32, eos_id=-1,
                               page_size=8, prefill_chunk=16)
    else:
        eng = ServeEngine(rt, max_batch=4, max_len=32, eos_id=-1)
    rids = [eng.add_request(**r) for r in wl]
    res = eng.run()
    return [res[r] for r in rids]


def main():
    cfg = get_smoke_config("qwen2-72b")
    key = jax.random.PRNGKey(0)
    rt_solo = ModelRuntime(cfg, key=key)
    bank_peft = {"g0": peft_lib.PEFTConfig(method="gsoft", block_size=8),
                 "b0": peft_lib.PEFTConfig(method="boft", block_size=8)}
    adapters = make_demo_adapters(list(bank_peft), rt_solo.params, bank_peft)
    wl = workload(8, adapters=[None, "g0", "b0"])

    ref = run_engine(rt_solo.attach(adapters, bank_peft), wl)

    for tp in (2, 4):
        rt_tp = ModelRuntime(cfg, key=key, mesh=serve_mesh(tp))
        got = run_engine(rt_tp.attach(adapters, bank_peft), wl)
        check(f"serve/tp{tp}/tokens_equal", got == ref)
        # the mesh runtime must actually shard — a silently replicated wq
        # would make every equality above vacuous
        paths = jax.tree_util.tree_flatten_with_path(rt_tp.params)[0]
        wq = next(l for p, l in paths
                  if "wq" in jax.tree_util.keystr(p))
        check(f"serve/tp{tp}/params_sharded",
              len(wq.sharding.device_set) == tp)

    # int8: QuantTensor q/scale leaves placed per-leaf on the mesh
    ref_q = run_engine(rt_solo.attach(adapters, bank_peft).quantized(), wl)
    rt_tp = ModelRuntime(cfg, key=key, mesh=serve_mesh(2))
    got_q = run_engine(rt_tp.attach(adapters, bank_peft).quantized(), wl)
    check("serve/tp2/int8_tokens_equal", got_q == ref_q)

    # paged engine: KV pages sharded over the head axis, table replicated
    wl_pg = workload(8, seed=1)
    ref_pg = run_engine(rt_solo, wl_pg, paged=True)
    got_pg = run_engine(ModelRuntime(cfg, key=key, mesh=serve_mesh(2)),
                        wl_pg, paged=True)
    check("serve/tp2/paged_tokens_equal", got_pg == ref_pg)

    for rec in OUT:
        print("CHECK " + json.dumps(rec))
    bad = [r for r in OUT if not r["ok"]]
    print(f"RESULT {len(OUT) - len(bad)}/{len(OUT)} ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
